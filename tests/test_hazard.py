"""Per-device hazard subsystem: ground-truth sampler determinism and
covariate behaviour, hazard-off invariance, the observational estimator,
hazard-keyed quarantine, risk-aware placement, the validation-as-fail-stop
path, engine parity with the vectorized heartbeat, and the ``aging_fleet``
acceptance row (risk-aware planner beats the hazard-blind planner)."""
import numpy as np
import pytest

from repro.cluster import scenarios
from repro.cluster.hazard import (
    HazardConfig,
    HazardEstimator,
    HazardModel,
    HazardPolicyConfig,
    expected_failures,
)
from repro.cluster.registry import ClusterTopology
from repro.cluster.scenarios import FailSlow, FailStop, PoissonFailures
from repro.cluster.simulator import SimConfig, TrainingSim
from repro.core.detector.lifecycle import (
    QUARANTINED,
    LifecycleConfig,
    LifecycleManager,
)
from repro.core.scheduler.scheduler import PlanOverheadModel, Scheduler
from repro.core.scheduler.plan import initial_plan
from repro.core.scheduler.tp_reconfig import reconfigure_tp_group

TOPO = ClusterTopology(4, 8)  # 32 devices

BENCH_CFG = SimConfig(dp=2, pp=4, tp=4, n_layers=40, n_microbatches=8,
                      seq_len=8192, noise=0.01, seed=0)
BASE_KW = {"plan_overhead_fixed": 0.25}

HAZARD_SCENARIOS = ("aging_fleet", "lemon_devices", "infant_mortality")


# ======================================================= sampler determinism
@pytest.mark.parametrize("name", HAZARD_SCENARIOS)
def test_hazard_scenarios_compile_deterministically(name):
    a = scenarios.get(name, span=128.0).compile(TOPO, seed=7).to_json()
    b = scenarios.get(name, span=128.0).compile(TOPO, seed=7).to_json()
    assert a == b
    assert a != scenarios.get(name, span=128.0).compile(TOPO, seed=8).to_json()


def test_hazard_model_sampling_deterministic():
    cfg = HazardConfig(mttf_s=100.0, shape=3.0, age_spread_s=50.0,
                       lemon_frac=0.2, lemon_factor=8.0)
    draws = []
    for _ in range(2):
        rng = np.random.default_rng(42)
        m = HazardModel(cfg, 16, rng)
        draws.append([m.sample_next(d, 0.0, rng) for d in range(16)])
    assert draws[0] == draws[1]


def test_hazard_failures_concentrate_on_repeat_offenders():
    """Renewal + wear: the same few devices fail again and again — the
    per-device realism a global-rate Poisson cannot produce."""
    tr = scenarios.get("aging_fleet", span=128.0).compile(TOPO, 0)
    victims = [e.target for e in tr if e.kind.startswith("fail")]
    top = max(victims.count(d) for d in set(victims))
    assert top >= 4  # at least one device fails many times
    assert len(set(victims)) < len(victims)  # recurrence, not distinct hits


def test_hazard_respects_repair_ordering():
    """A device never re-fails before its repair completed."""
    tr = scenarios.get("aging_fleet", span=128.0).compile(TOPO, 3)
    down_until = {}
    for ev in tr:
        if ev.kind.startswith("fail"):
            assert ev.t >= down_until.get(ev.target, 0.0)
        elif ev.kind == "rejoin":
            down_until[ev.target] = ev.t


# ================================================= ground-truth covariates
def test_weibull_shape_controls_aging_direction():
    rng = np.random.default_rng(0)
    wear = HazardModel(HazardConfig(mttf_s=100.0, shape=3.0), 1, rng)
    infant = HazardModel(HazardConfig(mttf_s=100.0, shape=0.6), 1, rng)
    assert wear.rate(0, 200.0) > wear.rate(0, 50.0)  # k>1: old fails more
    assert infant.rate(0, 200.0) < infant.rate(0, 50.0)  # k<1: burn-in


def test_lemons_and_wear_raise_hazard():
    cfg = HazardConfig(mttf_s=100.0, shape=1.0, lemon_frac=0.5,
                       lemon_factor=10.0, wear_per_repair=2.0)
    m = HazardModel(cfg, 64, np.random.default_rng(1))
    assert 0 < int(m.lemons.sum()) < 64
    lemon = int(np.argmax(m.lemons))
    clean = int(np.argmin(m.lemons))
    assert m.rate(lemon, 10.0) > m.rate(clean, 10.0)
    before = m.rate(clean, 10.0)
    m.record_repair(clean)
    assert m.rate(clean, 10.0) == pytest.approx(2.0 * before)


def test_expected_failures_monotone_in_horizon():
    m = HazardModel(HazardConfig(mttf_s=300.0, shape=3.0, age_spread_s=100.0),
                    32, np.random.default_rng(0))
    assert 0.0 < expected_failures(m, 50.0) < expected_failures(m, 200.0)


def test_hazard_config_validation():
    with pytest.raises(ValueError):
        HazardConfig(mttf_s=-1.0)
    with pytest.raises(ValueError):
        HazardConfig(lemon_frac=1.5)
    with pytest.raises(ValueError):
        HazardConfig(wear_per_repair=0.5)


# ====================================================== hazard-off invariance
def test_poisson_without_hazard_unchanged():
    """The ``hazard`` field must not perturb the legacy global-rate stream:
    a hazard-less PoissonFailures compiles to the identical timeline it did
    before the field existed. The derived-RNG stream key is
    ``crc32(repr(self))``, so the repr contract is the invariant: no
    ``hazard`` mention when unset (pre-hazard byte-identity), appended when
    set (distinct hazard configs draw distinct streams)."""
    kw = dict(rate=0.5, t_end=100.0, mttr=10.0)
    assert "hazard" not in repr(PoissonFailures(**kw))
    assert repr(PoissonFailures(**kw)) == (
        "PoissonFailures(rate=0.5, t_end=100.0, t_start=0.0, mix=0.5, "
        "severity=(0.3, 0.6), mttr=10.0, max_events=64, renewal=False)")
    assert "hazard=HazardConfig" in repr(
        PoissonFailures(**kw, hazard=HazardConfig()))
    tr = PoissonFailures(**kw).compile(TOPO, 9)
    fails = [ev for ev in tr if ev.kind in ("fail-stop", "fail-slow")]
    targets = [ev.target for ev in fails]
    assert len(targets) == len(set(targets))  # distinct-device contract holds
    assert {ev.target for ev in tr if ev.kind == "rejoin"} == set(targets)
    assert tr.to_json() == PoissonFailures(**kw).compile(TOPO, 9).to_json()


def test_hazard_switch_off_is_identical_policy():
    """``ResiHPPolicy(hazard=None)`` (the default) must run byte-identical
    to the pre-hazard code — same trace, same detector stats."""
    streams = []
    for kw in ({}, {}):
        sim = TrainingSim("resihp", BENCH_CFG, policy_kwargs={**BASE_KW, **kw})
        sim.apply_scenario(scenarios.get("flapping_stragglers", span=100.0))
        sim.run(60, stop_on_abort=False)
        streams.append(([(r.iteration, r.t_start, r.duration, r.throughput)
                         for r in sim.trace], sim.detector.stats.as_dict()))
    assert streams[0] == streams[1]
    assert TrainingSim("resihp", BENCH_CFG).hazard_estimator is None


# ============================================================= the estimator
def _hist(mgr, device, stops=(), slows=()):
    for t in stops:
        mgr.record_failstop(device, t)
    for t in slows:
        mgr.record_failslow(device, 0.5, t)
    return mgr.history(device)


def test_estimator_baseline_risk_is_one():
    est = HazardEstimator(HazardPolicyConfig())
    assert est.risk(None, 100.0) == pytest.approx(1.0)
    mgr = LifecycleManager()
    h = _hist(mgr, 3, slows=[10.0])
    # an in-window failure raises risk strictly above baseline ...
    assert est.risk(h, 20.0) > 1.0
    # ... and decays back to exactly 1.0 once it ages out of the window —
    # never *below* baseline (the bug that made the planner prefer lemons
    # in their quiet windows)
    assert est.risk(h, 10.0 + est.cfg.window_s + 1.0) == pytest.approx(1.0)


def test_estimator_counts_failslows_and_quarantines_repeaters():
    cfg = HazardPolicyConfig()  # ratio 4 with prior 0.5 => 2 recent failures
    est = HazardEstimator(cfg)
    mgr = LifecycleManager()
    h = _hist(mgr, 3, slows=[10.0, 30.0])
    assert est.risk(h, 35.0) == pytest.approx(5.0)  # 1 + 2 per failure
    assert est.should_quarantine(h, 35.0)
    assert not est.should_quarantine(_hist(mgr, 4, slows=[10.0]), 35.0)


def test_estimator_backoff_scales_and_caps():
    est = HazardEstimator(HazardPolicyConfig())
    mgr = LifecycleManager()
    mild = _hist(mgr, 1, slows=[10.0, 20.0])
    hot = _hist(mgr, 2, slows=[10.0, 12.0, 14.0, 16.0, 18.0, 20.0])
    kw = dict(base_s=40.0, max_s=1200.0, level=1, factor=2.0)
    assert est.backoff_s(mild, 25.0, **kw) >= 40.0
    assert est.backoff_s(hot, 25.0, **kw) > est.backoff_s(mild, 25.0, **kw)
    assert est.backoff_s(hot, 25.0, base_s=40.0, max_s=50.0, level=5,
                         factor=2.0) == 50.0


def test_hazard_keyed_quarantine_catches_failslow_repeater():
    """The flap counter only counts fail-stops: a part that keeps coming
    back *degraded* never quarantines under it, but does under the hazard
    estimator — the exact blind spot the ISSUE names."""
    est = HazardEstimator(HazardPolicyConfig())
    blind = LifecycleManager(cfg=LifecycleConfig(), probe_fn=lambda d: 1.0)
    aware = LifecycleManager(cfg=LifecycleConfig(), probe_fn=lambda d: 1.0,
                             hazard=est)
    for mgr in (blind, aware):
        mgr.record_failslow(7, 0.4, 10.0)
        mgr.record_failslow(7, 0.4, 25.0)
    assert blind.on_rejoin(7, 30.0).admit  # flap counter saw 0 fail-stops
    dec = aware.on_rejoin(7, 30.0)
    assert not dec.admit and dec.state == QUARANTINED
    assert aware.quarantined(31.0) == frozenset({7})
    assert aware.risk_scores(31.0)[7] > 1.0


# ======================================================= risk-aware planning
def test_tp_reconfig_risk_tiebreak():
    speeds = {0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0, 4: 1.0}
    # no risk: legacy ordering (stable sort keeps pool order on ties)
    rec = reconfigure_tp_group([0, 1, 2, 3, 4], speeds)
    assert rec.devices == (0, 1, 2, 3)
    # device 1 is a known repeater: equal-speed tie breaks away from it
    risky = reconfigure_tp_group([0, 1, 2, 3, 4], speeds,
                                 risk={1: 5.0})
    assert 1 not in risky.devices and risky.tp == 4
    assert risky.standby == (1,)
    # Eq. 4 still decides throughput: a fast high-risk device beats a slow
    # low-risk one (risk is a tie-break, not a veto)
    rec2 = reconfigure_tp_group([0, 1], {0: 1.0, 1: 0.2}, risk={0: 9.0})
    assert rec2.devices == (0,)


def test_scheduler_adapt_risk_prefers_low_hazard_standby():
    plan = initial_plan(8, dp=1, pp=2, tp=4)
    sch = Scheduler(layer_costs=[1.0] * 8)
    speeds = {d: 1.0 for d in plan.devices}
    speeds[1] = 0.0  # failure in stage 0 forces a group reconfig
    blind = sch.adapt(plan, speeds)
    aware = sch.adapt(plan, speeds, device_risk={2: 6.0})
    # both exclude the dead device and keep a tp2 subgroup ...
    assert blind.plan.replicas[0].stages[0].tp == 2
    assert aware.plan.replicas[0].stages[0].tp == 2
    # ... but the risk-aware one benches the known repeater on the tie
    assert 2 in blind.plan.replicas[0].stages[0].devices
    assert 2 not in aware.plan.replicas[0].stages[0].devices
    assert any("risk-aware" in n for n in aware.notes)
    # risk=None keeps byte-identical plans (hazard-blind contract)
    again = sch.adapt(plan, speeds)
    assert again.plan == blind.plan


def test_risk_is_exposure_free():
    """The decision score depends only on the recent failure count — the
    exposure terms cancel by construction, so the same history scores the
    same no matter when in the session it is evaluated."""
    mgr = LifecycleManager()
    h = _hist(mgr, 9, slows=[100.0, 110.0])
    for pt in (1.0, 400.0, 1e9):
        est = HazardEstimator(HazardPolicyConfig(prior_time_s=pt))
        assert est.risk(h, 115.0) == pytest.approx(5.0)
        assert est.risk(h, 140.0) == pytest.approx(5.0)


# ======================================================== plan-overhead model
def test_plan_overhead_model_fit_and_predict():
    true = PlanOverheadModel(coef=1.4, intercept=-17.0)
    samples = [(d, l, true.predict(d, l)) for d, l in
               ((16, 28), (32, 48), (64, 64), (128, 80))]
    fit = PlanOverheadModel.fit(samples)
    assert fit.coef == pytest.approx(1.4, rel=1e-6)
    assert fit.fit_mape < 1e-6
    assert fit.predict(64, 64) == pytest.approx(true.predict(64, 64), rel=1e-6)
    with pytest.raises(ValueError):
        PlanOverheadModel.fit([(16, 28, 1e-4)])


def test_plan_overhead_model_is_deterministic_in_sim():
    """``plan_overhead_model`` replaces the measured wall-clock charge with
    the fitted curve: two runs produce identical reconfig charges (the
    measured path does not — that is the ROADMAP item this closes)."""
    charges = []
    for _ in range(2):
        sim = TrainingSim("resihp", BENCH_CFG,
                          policy_kwargs={"plan_overhead_model": True})
        sim.apply_scenario(scenarios.get("fig10_mixed", span=30.0))
        sim.run(50, stop_on_abort=False)
        charges.append([e[1] for r in sim.trace for e in r.events
                        if e[0] == "reconfig"])
    assert charges[0] == charges[1] and charges[0]
    model = PlanOverheadModel()
    predicted = model.predict(BENCH_CFG.n_devices, BENCH_CFG.n_layers)
    # every reconfig charge embeds the modeled (not measured) planning term
    assert all(c >= predicted for c in charges[0])


# ================================================ validation as fail-stop
def test_validation_doubles_as_failstop_path():
    """A device that died just before a validation pass is reported by the
    pass itself (lifecycle on): belief flips immediately and the heartbeat
    never re-reports (no second stall). Lifecycle off: the same death waits
    out the heartbeat window — the ROADMAP gap this closes."""
    scen = (FailSlow(device=21, severity=0.35, at=10.0)
            + FailStop(at=14.0, device=3))
    lc = TrainingSim("resihp", BENCH_CFG,
                     policy_kwargs={**BASE_KW, "lifecycle": True})
    lc.apply_scenario(scen)
    lc.run(40, stop_on_abort=False)
    ev = [(r.iteration, e) for r in lc.trace for e in r.events]
    via_val = [it for it, e in ev if e[0] == "failstop-via-validation"]
    assert via_val, "validation pass did not report the dead device"
    assert not any(e[0] == "fail-stop-detected" and 3 in e[1] for _, e in ev)
    assert lc.known_speeds[3] == 0.0
    assert lc.lifecycle.histories[3].fail_stops  # recorded as a fail-stop

    # lifecycle off (the paper's behaviour): the same death is only ever
    # detected by the heartbeat timeout — validation never reports it, and
    # the NCCL-stall charge is paid
    base = TrainingSim("resihp", BENCH_CFG, policy_kwargs=BASE_KW)
    base.apply_scenario(scen)
    base.run(40, stop_on_abort=False)
    bev = [e for r in base.trace for e in r.events]
    assert not any(e[0] == "failstop-via-validation" for e in bev)
    assert any(e[0] == "fail-stop-detected" and 3 in e[1] for e in bev)


# ================================================== engine parity (heartbeat)
PARITY_CFG = SimConfig(dp=2, pp=4, tp=2, n_layers=16, n_microbatches=4,
                       seq_len=2048, noise=0.01, seed=0)  # 16 devices, 2 nodes


@pytest.mark.parametrize("scenario,kw", [
    ("aging_fleet", dict(span=60.0)),
    ("lemon_devices", dict(span=60.0)),
    ("rack_storm", dict(at=8.0, recover_after=25.0)),
])
def test_hazard_engine_parity(scenario, kw):
    """python (reference HeartbeatMonitor, per-device loops) vs fast
    (FastHeartbeat + StageSpeedCache) with the hazard subsystem on — the
    parity pin for the vectorized ``_sync_beliefs`` path, including node
    death/recovery and hazard rejoin storms."""
    streams = []
    for engine in ("python", "fast"):
        sim = TrainingSim("resihp", PARITY_CFG, engine=engine,
                          policy_kwargs={**BASE_KW, "hazard": True})
        sim.apply_scenario(scenarios.get(scenario, **kw))
        sim.run(60, stop_on_abort=False)
        streams.append(([(r.iteration, r.t_start, r.duration, r.throughput)
                         for r in sim.trace],
                        [ev.as_tuple() for ev in sim.event_log],
                        sim.detector.stats.as_dict(),
                        sim.lifecycle.stats.as_dict(),
                        dict(sim.known_speeds)))
    assert streams[0] == streams[1]


def test_fast_heartbeat_unit_parity():
    """Scripted beat/death/revive sequence through both monitors: identical
    newly-failed reports at every sweep (device-level, whole-node and
    revive-after-node-death paths)."""
    from repro.cluster.fastsim import FastHeartbeat
    from repro.core.detector.heartbeat import HeartbeatMonitor

    def build(cls):
        hb = cls(interval=1.0, miss_threshold=3)
        for n in range(2):
            hb.register_node(n, [n * 4 + i for i in range(4)])
        return hb

    ref, fast = build(HeartbeatMonitor), build(FastHeartbeat)
    alive = {d: True for d in range(8)}

    def beat(now):
        for d, up in alive.items():
            if up:
                ref.device_beat(d // 4, d, now)
                ref.node_beat(d // 4, now)
        fast.beat_all(np.array([alive[d] for d in range(8)]), now)

    log = []
    for t in range(20):
        now = float(t)
        if t == 3:
            alive[2] = False  # single device dies
        if t == 9:
            for d in (4, 5, 6, 7):
                alive[d] = False  # whole node goes dark
        if t == 15:
            alive[2] = True  # repaired: revive through both monitors
            ref.revive(2, now)
            fast.revive(2, now)
        if t == 17:
            alive[4] = True  # node-resident device returns (revives node)
            ref.revive(4, now)
            fast.revive(4, now)
        beat(now)
        a, b = ref.sweep(now), fast.sweep(now)
        assert a == b, (t, a, b)
        log.append(a)
    assert any(log)  # the sequence actually exercised failures
    assert ref.failed_devices == fast.failed_devices
    assert ref.failed_nodes == fast.failed_nodes
    # second deaths after revive are detectable in both
    alive[2] = False
    for t in range(20, 26):
        beat(float(t))
        a, b = ref.sweep(float(t)), fast.sweep(float(t))
        assert a == b
    assert 2 in ref.failed_devices and 2 in fast.failed_devices


# ==================================================== the acceptance bench row
def test_bench_aging_fleet_risk_aware_beats_hazard_blind():
    """With ``aging_fleet`` on, the risk-aware planner (``resihp+hz``) beats
    the hazard-blind one (``resihp+lc``) on **session throughput** — samples
    per second of elapsed time, reconfiguration storms included, the metric
    the hazard subsystem exists to improve — in the exact configuration
    ``bench_scenarios`` runs.

    Under the corrected layer-transfer accounting (reconfigurations diff
    against the *previous* plan, so repeat exclusions stop overpaying) the
    per-iteration execution throughputs of the two land within a few percent
    of each other at this seed, with either side on top depending on how the
    quarantine timeline shakes out — so only the session metric, where the
    hazard win is structural (fewer storms to pay for), is pinned."""
    from benchmarks.bench_scenarios import run as bench_run

    hz = bench_run("llama2-13b", "aging_fleet", "resihp+hz", iters=160)
    lc = bench_run("llama2-13b", "aging_fleet", "resihp+lc", iters=160)
    assert not hz["aborted"] and not lc["aborted"]
    assert hz["session_throughput"] > lc["session_throughput"]
    # the blind spot is real: hazard-keyed quarantine catches repeat
    # offenders the flap counter alone cannot (the blind policy's rare
    # quarantine is a flapper that happened to cross the count threshold)
    assert hz["lifecycle"]["quarantines"] > lc["lifecycle"]["quarantines"]
    assert hz["lifecycle"]["rejoins_deferred"] > lc["lifecycle"]["rejoins_deferred"]


# ==================================================== the pooled estimator
def _dom_hist(device, stops=(), slows=()):
    from repro.core.detector.lifecycle import FailureHistory

    return FailureHistory(device, fail_stops=list(stops),
                          fail_slows=list(slows))


def test_domain_estimator_fires_before_third_device_fails():
    """Two distinct residents of one rack failing inside the window push the
    pooled risk past threshold — the rack is benched before any third
    device dies. Defaults: risk = 1 + n/0.5, threshold 4 => two pooled
    events from >= 2 distinct devices trip it."""
    from repro.cluster.hazard import DomainEstimator, DomainPolicyConfig

    est = DomainEstimator(DomainPolicyConfig())
    rack = [_dom_hist(8, stops=[10.0]), _dom_hist(9, stops=[40.0]),
            _dom_hist(10), _dom_hist(11)]
    assert est.risk(rack, 50.0) == 5.0
    assert est.should_quarantine(rack, 50.0)
    # the same evidence aged past the window releases the domain
    assert not est.should_quarantine(rack, 110.0)


def test_domain_estimator_silent_when_failures_spread_across_domains():
    """The same two failures on devices of *different* racks never
    quarantine either rack: each pools one event (risk 3 < threshold 4,
    one distinct device < min_devices 2). Correlation — not count — is the
    signal."""
    from repro.cluster.hazard import DomainEstimator, DomainPolicyConfig

    est = DomainEstimator(DomainPolicyConfig())
    rack_a = [_dom_hist(0, stops=[10.0]), _dom_hist(1), _dom_hist(2)]
    rack_b = [_dom_hist(8, stops=[40.0]), _dom_hist(9), _dom_hist(10)]
    assert not est.should_quarantine(rack_a, 50.0)
    assert not est.should_quarantine(rack_b, 50.0)


def test_domain_estimator_one_repeat_offender_is_not_a_rack_problem():
    """Three failures on ONE resident keep the pooled risk elevated but
    never quarantine the rack (min_devices=2): a single lemon is the
    per-device estimator's job; benching its seven healthy neighbours
    would be pure loss."""
    from repro.cluster.hazard import DomainEstimator, DomainPolicyConfig

    est = DomainEstimator(DomainPolicyConfig())
    rack = [_dom_hist(8, stops=[10.0, 20.0, 30.0]), _dom_hist(9), _dom_hist(10)]
    assert est.risk(rack, 35.0) == 7.0  # well past threshold...
    assert not est.should_quarantine(rack, 35.0)  # ...but 1 device only


def test_domain_estimator_reduces_to_hazard_estimator_on_single_device():
    """On a one-device domain the pooled risk equals the per-device
    estimator's risk for the same history — same prior, same window, same
    fail-stop+fail-slow evidence — so domain pooling is a strict
    generalization, not a second calibration to keep in sync."""
    from repro.cluster.hazard import (DomainEstimator, DomainPolicyConfig,
                                      HazardEstimator, HazardPolicyConfig)

    h = _dom_hist(3, stops=[5.0, 30.0], slows=[(42.0, 0.4)])
    dom = DomainEstimator(DomainPolicyConfig())
    per = HazardEstimator(HazardPolicyConfig())
    for now in (6.0, 31.0, 45.0, 70.0, 200.0):
        assert dom.risk([h], now) == per.risk(h, now)
