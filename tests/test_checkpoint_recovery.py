"""Checkpoint (atomic, double-buffered, reshard-on-load) + Fig. 8 recovery."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import get_arch, reduced
from repro.core.recovery import recover_state, transfer_plan
from repro.core.scheduler.plan import ParallelPlan, ReplicaPlan, StagePlan, initial_plan
from repro.core.scheduler.scheduler import Scheduler


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 8)), "b": jnp.zeros((8,))},
        "opt": {"m": jnp.ones((8, 8))},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    s = _state()
    save_checkpoint(tmp_path, s, 7)
    r, step, extra = restore_checkpoint(tmp_path, target=s)
    assert step == 7
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_commit_ignored_without_marker(tmp_path):
    s = _state()
    save_checkpoint(tmp_path, s, 5)
    # simulate a crash mid-save at step 10: directory without COMMIT
    d = tmp_path / "step_000000010"
    d.mkdir()
    (d / "MANIFEST.json").write_text(json.dumps({"n_leaves": 0}))
    assert latest_step(tmp_path) == 5
    _, step, _ = restore_checkpoint(tmp_path, target=s)
    assert step == 5


def test_gc_keeps_last_k(tmp_path):
    s = _state()
    for step in (1, 2, 3, 4):
        save_checkpoint(tmp_path, s, step, keep=2)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2 and steps[-1].endswith("4")


def test_manager_interval(tmp_path):
    mgr = CheckpointManager(tmp_path, interval=5)
    s = _state()
    assert mgr.maybe_save(s, 3) is None
    assert mgr.maybe_save(s, 5) is not None
    assert mgr.has_checkpoint()


def test_extra_payload(tmp_path):
    save_checkpoint(tmp_path, _state(), 1, extra={"data_cursor": 123})
    _, _, extra = restore_checkpoint(tmp_path, target=_state())
    assert extra["data_cursor"] == 123


# ------------------------------------------- crash-consistency properties
# Fault injection at every write the save path performs: whatever instant
# the process dies, the latest *committed* checkpoint must stay restorable
# bit-for-bit and `latest_step` must never name the torn write.
class _Boom(RuntimeError):
    pass


@pytest.mark.parametrize("crash_leaf", [0, 1, 2])
def test_crash_at_any_leaf_write_leaves_no_commit(tmp_path, monkeypatch,
                                                  crash_leaf):
    s = _state()
    save_checkpoint(tmp_path, s, 5)
    calls = {"n": 0}
    real_save = np.save

    def dying_save(path, arr, *a, **kw):
        if calls["n"] == crash_leaf:
            raise _Boom(f"killed at leaf {crash_leaf}")
        calls["n"] += 1
        return real_save(path, arr, *a, **kw)

    monkeypatch.setattr(np, "save", dying_save)
    with pytest.raises(_Boom):
        save_checkpoint(tmp_path, _state(seed=1), 10)
    monkeypatch.undo()
    # the torn write never became a committed step directory
    assert not (tmp_path / "step_000000010").exists()
    assert not (tmp_path / "step_000000010.tmp" / "COMMIT").exists()
    assert latest_step(tmp_path) == 5
    r, step, _ = restore_checkpoint(tmp_path, target=s)
    assert step == 5
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the crashed step's stale .tmp does not poison the next save
    save_checkpoint(tmp_path, _state(seed=2), 10)
    assert latest_step(tmp_path) == 10


def test_crash_at_commit_marker_write(tmp_path, monkeypatch):
    """Death between the manifest write and the COMMIT marker: everything is
    on disk except the one byte that makes it real — restore must still fall
    back to the previous committed step."""
    s = _state()
    save_checkpoint(tmp_path, s, 5)
    real_write = Path.write_text

    def dying_write(self, text, *a, **kw):
        if self.name == "COMMIT":
            raise _Boom("killed at commit")
        return real_write(self, text, *a, **kw)

    monkeypatch.setattr(Path, "write_text", dying_write)
    with pytest.raises(_Boom):
        save_checkpoint(tmp_path, _state(seed=1), 10)
    monkeypatch.undo()
    # the rename never ran: the full payload sits in .tmp, invisible
    assert (tmp_path / "step_000000010.tmp" / "MANIFEST.json").exists()
    assert not (tmp_path / "step_000000010").exists()
    assert latest_step(tmp_path) == 5
    _, step, _ = restore_checkpoint(tmp_path, target=s)
    assert step == 5


def test_gc_never_deletes_latest_committed(tmp_path):
    """Pruning property: under `keep=1` amid uncommitted/torn debris with
    *higher* step numbers, the latest committed step always survives and the
    debris is neither promoted nor counted against the keep budget."""
    s = _state()
    for step in (1, 2, 3):
        save_checkpoint(tmp_path, s, step, keep=1)
        # torn higher-numbered neighbors around every save
        torn = tmp_path / f"step_{step + 100:09d}"
        torn.mkdir()
        (torn / "MANIFEST.json").write_text(json.dumps({"n_leaves": 0}))
        stale = tmp_path / f"step_{step + 200:09d}.tmp"
        stale.mkdir()
        assert latest_step(tmp_path) == step
        _, got, _ = restore_checkpoint(tmp_path, target=s)
        assert got == step
    committed = [p.name for p in tmp_path.glob("step_*")
                 if (p / "COMMIT").exists()]
    assert committed == ["step_000000003"]


def test_save_plan_a_restore_plan_b_bit_exact(tmp_path):
    """Reshard-on-load: a checkpoint written under one plan's shardings is
    restored straight into a *different* plan's shardings (Fig. 8b recovery
    into the post-adaptation layout) — placement changes, bits do not."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
    plan_a = {"params": {"w": NamedSharding(mesh, P("x", None)),
                         "b": NamedSharding(mesh, P(None))},
              "opt": {"m": NamedSharding(mesh, P(None, "x"))},
              "step": None}
    plan_b = {"params": {"w": NamedSharding(mesh, P(None, "x")),
                         "b": NamedSharding(mesh, P("x"))},
              "opt": {"m": NamedSharding(mesh, P("x", None))},
              "step": None}
    s = _state()
    placed = jax.tree.map(
        lambda leaf, sh: jax.device_put(leaf, sh) if sh is not None else leaf,
        s, plan_a)
    save_checkpoint(tmp_path, placed, 7)
    r, step, _ = restore_checkpoint(tmp_path, target=s, shardings=plan_b)
    assert step == 7
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert r["params"]["w"].sharding.spec == P(None, "x")
    assert r["opt"]["m"].sharding.spec == P("x", None)


# -------------------------------------------------------------- Fig. 8
def test_transfer_plan_layer_moves():
    cfg = reduced(get_arch("qwen3-8b"), n_layers=8)
    old = initial_plan(8, dp=2, pp=4, tp=2)  # (2,2,2,2)
    sch = Scheduler(layer_costs=[1.0] * 8)
    speeds = {d: 1.0 for d in old.devices}
    speeds[old.replicas[0].stages[1].devices[0]] = 0.0
    ad = sch.adapt(old, speeds)
    tp = transfer_plan(cfg, old, ad.plan, dead_stages=ad.dead_stages)
    assert not tp.restore_required
    # the slowed stage lost layers; every move has a live source replica
    assert all(m.src_replica >= 0 for m in tp.moves)
    assert tp.total_bytes > 0
    assert tp.seconds() >= 0


def test_transfer_plan_restore_required_when_no_source():
    cfg = reduced(get_arch("qwen3-8b"), n_layers=4)
    old = initial_plan(4, dp=2, pp=2, tp=1)
    # new plan moves layer 1 from stage 0 to stage 1, but stage 0 is dead in
    # both replicas -> no live source
    new = ParallelPlan(tuple(
        ReplicaPlan((StagePlan(r.stages[0].devices, (0,)),
                     StagePlan(r.stages[1].devices, (1, 2, 3))))
        for r in old.replicas
    ))
    tp = transfer_plan(cfg, old, new, dead_stages=[(0, 0), (1, 0)])
    assert tp.restore_required


def test_recover_state_fig8b_checkpoint_fallback(tmp_path):
    cfg = reduced(get_arch("qwen3-8b"), n_layers=4)
    old = initial_plan(4, dp=2, pp=2, tp=1)
    new = ParallelPlan(tuple(
        ReplicaPlan((StagePlan(r.stages[0].devices, (0,)),
                     StagePlan(r.stages[1].devices, (1, 2, 3))))
        for r in old.replicas
    ))
    state = _state()
    mgr = CheckpointManager(tmp_path, interval=1)
    # no checkpoint -> hard error (training cannot continue)
    with pytest.raises(RuntimeError):
        recover_state(cfg, state, old_plan=old, new_plan=new,
                      shardings=jax.tree.map(lambda _: None, state),
                      checkpoint_mgr=mgr, dead_stages=[(0, 0), (1, 0)])
    mgr.maybe_save(state, 1)
    got, tp, step = recover_state(
        cfg, state, old_plan=old, new_plan=new,
        shardings=jax.tree.map(lambda _: None, state),
        checkpoint_mgr=mgr, dead_stages=[(0, 0), (1, 0)])
    assert step == 1 and tp.restore_required
    np.testing.assert_array_equal(
        np.asarray(got["params"]["w"]), np.asarray(state["params"]["w"]))
