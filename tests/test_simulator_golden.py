"""Golden regression: a small simulator run under a named scenario must
reproduce the checked-in event trace and throughput exactly.

Guards the ClusterSimulator/scenario refactor: any change to event
compilation, firing order, detection latency or throughput accounting shows
up as a diff against ``tests/golden/simulator_golden.json``.

Regenerate (after an *intentional* behavior change) with:

    PYTHONPATH=src:tests python -c "import test_simulator_golden as g; g.regenerate()"
"""
import json
from pathlib import Path

import pytest

from repro.cluster import scenarios
from repro.cluster.simulator import SimConfig, TrainingSim

GOLDEN_PATH = Path(__file__).parent / "golden" / "simulator_golden.json"

CFG = SimConfig(dp=2, pp=2, tp=2, n_layers=8, n_microbatches=4,
                seq_len=2048, noise=0.01, seed=0)
SCENARIO = "fig10_mixed"
SPAN = 3.0
ITERS = 60
# pin the one wall-clock-measured quantity (planning time, Fig. 13) so the
# whole run — including now-timestamps — is machine-independent
POLICY_KW = dict(plan_overhead_fixed=0.25)


def _run():
    sim = TrainingSim("resihp", CFG, policy_kwargs=POLICY_KW)
    compiled = sim.apply_scenario(scenarios.get(SCENARIO, span=SPAN))
    sim.run(ITERS, stop_on_abort=False)
    return sim, compiled


def _observed(sim, compiled) -> dict:
    return {
        "scenario": SCENARIO,
        "compiled_events": compiled.as_tuples(),
        "fired_events": [ev.as_tuple() for ev in sim.event_log],
        "cluster_log": [[t, kind, int(target), float(value)]
                        for t, kind, target, value in sim.cluster.events],
        "n_iters": len(sim.trace),
        "aborted": sim.aborted,
        "avg_throughput": sim.avg_throughput(skip=2),
        # elapsed-time view: t_start and the session throughput see the
        # reconfiguration / stall / probe charges that per-iteration
        # durations deliberately exclude — without them a change to
        # overhead accounting (e.g. the layer-transfer charge) would be
        # invisible to this golden
        "session_throughput": sim.session_throughput(skip=2),
        "t_starts": [r.t_start for r in sim.trace],
        "durations": [r.duration for r in sim.trace],
        "iter_events": [[e[0] for e in r.events] for r in sim.trace],
    }


def regenerate():
    sim, compiled = _run()
    GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
    GOLDEN_PATH.write_text(json.dumps(_observed(sim, compiled), indent=1))
    print(f"wrote {GOLDEN_PATH}")


@pytest.fixture(scope="module")
def golden():
    assert GOLDEN_PATH.exists(), "golden missing - run regenerate()"
    return json.loads(GOLDEN_PATH.read_text())


@pytest.fixture(scope="module")
def observed():
    sim, compiled = _run()
    # JSON-normalize (tuples -> lists) so comparisons are type-stable
    return json.loads(json.dumps(_observed(sim, compiled)))


def test_compiled_event_trace_matches_golden(golden, observed):
    assert observed["compiled_events"] == golden["compiled_events"]


def test_fired_events_match_golden(golden, observed):
    assert observed["fired_events"] == golden["fired_events"]
    assert observed["cluster_log"] == golden["cluster_log"]


def test_iteration_shape_matches_golden(golden, observed):
    assert observed["n_iters"] == golden["n_iters"]
    assert observed["aborted"] == golden["aborted"]
    assert observed["iter_events"] == golden["iter_events"]


def test_throughput_matches_golden(golden, observed):
    assert observed["avg_throughput"] == pytest.approx(
        golden["avg_throughput"], rel=1e-9)
    assert observed["durations"] == pytest.approx(
        golden["durations"], rel=1e-9)


def test_elapsed_time_matches_golden(golden, observed):
    """Overhead accounting (reconfig / stall charges advancing ``now``) is
    pinned through the iteration start times and the session throughput."""
    assert observed["session_throughput"] == pytest.approx(
        golden["session_throughput"], rel=1e-9)
    assert observed["t_starts"] == pytest.approx(golden["t_starts"], rel=1e-9)
