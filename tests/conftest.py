"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the real device count
(1 on this container); multi-device paths are exercised via subprocesses."""
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavy jax compile/train tests; tier-1 runs -m 'not slow'")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_packed(rng, B, S, doc_lens=None):
    """Packed (segment_ids, positions) arrays for attention/kernel tests."""
    seg = np.zeros((B, S), np.int32)
    pos = np.zeros((B, S), np.int32)
    for b in range(B):
        lens = doc_lens or []
        if not lens:
            remaining, lens = S, []
            while remaining > 0:
                l = int(rng.integers(max(S // 8, 1), S + 1))
                l = min(l, remaining)
                lens.append(l)
                remaining -= l
        off = 0
        for i, l in enumerate(lens):
            if off + l > S:
                l = S - off
            if l <= 0:
                break
            seg[b, off: off + l] = i + 1
            pos[b, off: off + l] = np.arange(l)
            off += l
    return seg, pos
