"""Failure-scenario DSL: deterministic compilation, composition, correlated
rack locality, registry coverage, timeline validation hardening and the
legacy inject_at shim."""
import json

import pytest

from repro.cluster import scenarios
from repro.cluster.events import Event, EventTrace, TraceValidationError
from repro.cluster.registry import ClusterState, ClusterTopology
from repro.cluster.scenarios import (
    Compose,
    CorrelatedRackStorm,
    FailSlow,
    FailStop,
    MixedFailures,
    NetworkDegrade,
    PoissonFailures,
    TransientFlap,
)
from repro.cluster.simulator import SimConfig, TrainingSim

TOPO = ClusterTopology(8, 8)  # 64 devices

SMALL = SimConfig(dp=2, pp=2, tp=2, n_layers=8, n_microbatches=4,
                  seq_len=2048, noise=0.0)


# ------------------------------------------------------------- determinism
@pytest.mark.parametrize("name", [
    "fig10_mixed", "fig14_largescale", "table6_failstop", "rack_storm",
    "flapping_stragglers", "slow_ramp_mix", "poisson_storm",
])
def test_compile_deterministic(name):
    """Same seed => byte-identical compiled event trace."""
    a = scenarios.get(name).compile(TOPO, seed=7).to_json()
    b = scenarios.get(name).compile(TOPO, seed=7).to_json()
    assert a == b
    assert a.encode() == b.encode()  # byte-identical serialization


def test_different_seeds_differ():
    s = scenarios.get("poisson_storm")
    assert s.compile(TOPO, 0).to_json() != s.compile(TOPO, 1).to_json()


def test_trace_roundtrip():
    tr = scenarios.get("fig10_mixed").compile(TOPO, 3)
    back = EventTrace.from_json(tr.to_json())
    assert back == tr and back.to_json() == tr.to_json()


def test_registry_names_cover_catalog():
    known = set(scenarios.names())
    for required in ("fig9_failslow", "fig10_mixed", "fig11_mixed",
                     "fig14_largescale", "table5_failslow", "table6_failstop",
                     "rack_storm", "rack_storm_256", "flapping_stragglers",
                     "flap_then_recover", "slow_ramp_mix", "poisson_storm",
                     "adversarial_1", "adversarial_2", "adversarial_3"):
        assert required in known
    with pytest.raises(KeyError):
        scenarios.get("no_such_scenario")


def test_every_catalog_scenario_validates_at_fig14_scale():
    """The whole registry compiles to contradiction-free timelines at the
    256-device scale every scenario supports (example_mixed and the mined
    adversarial family target literal Fig.-14-scale device ids, so 256
    devices is the topology the full catalog shares)."""
    topo = ClusterTopology(32, 8)
    for name in scenarios.names():
        for seed in (0, 7):
            scenarios.get(name).compile(topo, seed).validate(topo)


# ------------------------------------------------- validation hardening
def _tr(*rows):
    return EventTrace(Event(*row) for row in rows)


VTOPO = ClusterTopology(2, 4)  # 8 devices / 2 nodes

# name -> (trace rows, message fragment): one case per rejection rule in
# EventTrace.validate — sequences the adversarial mutator can generate and
# the simulator would otherwise silently mis-simulate
REJECTIONS = {
    "negative_time": ([(-1.0, "fail-stop", 0)], "finite and >= 0"),
    "nan_time": ([(float("nan"), "fail-stop", 0)], "finite and >= 0"),
    "inf_value": ([(1.0, "fail-slow", 0, float("inf"))], "must be finite"),
    "device_id_out_of_range": ([(1.0, "fail-stop", 8)], "device id out of"),
    "negative_device_id": ([(1.0, "rejoin", -1)], "device id out of"),
    "node_id_out_of_range": ([(1.0, "fail-stop-node", 2)], "node id out of"),
    "fail_slow_severity_zero": ([(1.0, "fail-slow", 0, 0.0)], "(0, 1]"),
    "fail_slow_severity_above_one": ([(1.0, "fail-slow", 0, 1.5)], "(0, 1]"),
    "rejoin_value_is_full_speed": (
        [(1.0, "fail-stop", 0), (2.0, "rejoin", 0, 1.0)],
        "encode_rejoin_speed"),
    "net_degrade_scale_zero": ([(1.0, "net-degrade", 0, 0.0)], "(0, 1]"),
    "double_fail_stop": (
        [(1.0, "fail-stop", 3), (2.0, "fail-stop", 3)], "already dead"),
    "fail_slow_on_dead_device": (
        [(1.0, "fail-stop", 3), (2.0, "fail-slow", 3, 0.5)], "dead device"),
    "node_kill_when_all_dead": (
        [(1.0, "fail-stop-node", 1), (2.0, "fail-stop-node", 1)],
        "already dead"),
    "rejoin_before_any_failure": (
        [(1.0, "rejoin", 5)], "before any failure"),
    "net_restore_without_degrade": (
        [(1.0, "net-restore", 0)], "without an active"),
}


@pytest.mark.parametrize("name", sorted(REJECTIONS))
def test_validate_rejects(name):
    rows, fragment = REJECTIONS[name]
    with pytest.raises(TraceValidationError, match="event "):
        _tr(*rows).validate(VTOPO)
    with pytest.raises(TraceValidationError) as exc:
        _tr(*rows).validate(VTOPO)
    assert fragment in str(exc.value)


def test_validate_accepts_legitimate_lifecycles():
    """The rules must not reject real patterns: kill->rejoin->kill flaps,
    rejoin after fail-slow (recovery), degraded returns, stacked
    net-degrades with one restore, node kill after a device kill on the
    same node."""
    _tr((1.0, "fail-stop", 0), (2.0, "rejoin", 0),
        (3.0, "fail-stop", 0)).validate(VTOPO)
    _tr((1.0, "fail-slow", 1, 0.5), (2.0, "rejoin", 1)).validate(VTOPO)
    _tr((1.0, "fail-stop", 2), (2.0, "rejoin", 2, 0.6),
        (3.0, "rejoin", 2)).validate(VTOPO)
    _tr((1.0, "net-degrade", 0, 0.5), (2.0, "net-degrade", 0, 0.8),
        (3.0, "net-restore", 0)).validate(VTOPO)
    _tr((1.0, "fail-stop", 4), (2.0, "fail-stop-node", 1)).validate(VTOPO)


def test_validate_returns_self_and_skips_callbacks():
    tr = _tr((1.0, "fail-stop", 0))
    assert tr.validate(VTOPO) is tr
    cb = EventTrace([Event(1.0, "callback", fn=lambda c, now: None)])
    cb.validate(VTOPO)  # opaque, skipped


def test_apply_scenario_validates_by_default():
    """The simulator rejects contradictory scenarios up front; the
    validate=False escape hatch replays them anyway (legacy behavior)."""
    from repro.cluster.scenarios import Rejoin

    sim = TrainingSim("resihp", SMALL)
    with pytest.raises(TraceValidationError):
        sim.apply_scenario(Rejoin(device=3, at=1.0))
    sim = TrainingSim("resihp", SMALL)
    tr = sim.apply_scenario(Rejoin(device=3, at=1.0), validate=False)
    assert len(tr) == 1


# ------------------------------------------------------------- composition
def test_compose_merges_in_time_order():
    a = FailStop(at=30.0, device=1)
    b = FailSlow(device=2, severity=0.5, at=10.0)
    tr = (a + b).compile(TOPO, 0)
    times = [ev.t for ev in tr]
    assert times == sorted(times)
    assert tr[0].kind == "fail-slow" and tr[1].kind == "fail-stop"


def test_compose_preserves_child_timelines():
    """A child compiles to the same events alone or inside a composition."""
    storm = MixedFailures(span=100.0, n_events=4)
    flap = TransientFlap(device=3, at=5.0, n_flaps=2)
    alone = storm.compile(TOPO, 11).as_tuples()
    composed = Compose([flap, storm]).compile(TOPO, 11).as_tuples()
    assert [e for e in composed if e[4] == "MixedFailures"] == alone


def test_compose_same_class_children_draw_independent_streams():
    """Two stochastic children of the same class must not mirror each other's
    random draws (device permutations would collide)."""
    a = MixedFailures(span=100.0, n_events=4)
    b = MixedFailures(span=200.0, n_events=4)
    tr = Compose([a, b]).compile(TOPO, 0)
    hits_a = [ev.target for ev in tr if ev.t <= 100.0 * 4 / 5]
    hits_b = [ev.target for ev in tr if ev.t > 100.0 * 4 / 5]
    assert hits_a != hits_b  # same devices in the same order = shared stream


def test_compose_chains():
    s = FailStop(at=1.0, device=0) + FailStop(at=2.0, device=1) \
        + FailStop(at=3.0, device=2)
    assert isinstance(s, Compose) and len(s.children) == 3
    assert len(s.compile(TOPO, 0)) == 3


# ----------------------------------------------------- rack-storm locality
def test_rack_storm_hits_exactly_colocated_devices():
    storm = CorrelatedRackStorm(at=10.0, racks=[3], stagger=0.5)
    tr = storm.compile(TOPO, 0)
    hit = sorted(ev.target for ev in tr if ev.kind == "fail-stop")
    expected = [d for d in range(TOPO.n_devices) if TOPO.node_of(d) == 3]
    assert hit == expected
    assert all(TOPO.node_of(ev.target) == 3 for ev in tr)


def test_rack_storm_random_rack_is_colocated_and_seeded():
    storm = CorrelatedRackStorm(at=5.0, n_racks=2)
    tr = storm.compile(TOPO, 4)
    racks = {TOPO.node_of(ev.target) for ev in tr}
    assert len(racks) == 2
    per_rack = {r: [ev for ev in tr if TOPO.node_of(ev.target) == r]
                for r in racks}
    for r, evs in per_rack.items():
        assert len(evs) == TOPO.devices_per_node  # whole rack, nothing else
    assert tr.to_json() == storm.compile(TOPO, 4).to_json()


def test_rack_storm_recovery_rejoins_every_victim():
    storm = CorrelatedRackStorm(at=10.0, racks=[0], recover_after=20.0)
    tr = storm.compile(TOPO, 0)
    down = {ev.target for ev in tr if ev.kind == "fail-stop"}
    up = {ev.target for ev in tr if ev.kind == "rejoin"}
    assert down == up


# ----------------------------------------------------------- event effects
def test_flap_restores_cluster_state():
    topo = ClusterTopology(2, 4)
    cluster = ClusterState(topo)
    tr = TransientFlap(device=2, at=1.0, n_flaps=2, down_time=1.0,
                       up_time=2.0).compile(topo, 0)
    from repro.cluster.events import apply_event

    for ev in tr:
        apply_event(ev, cluster, ev.t)
    assert cluster.devices[2].alive and cluster.devices[2].speed == 1.0
    kinds = [e[1] for e in cluster.events]
    assert kinds == ["fail-stop", "repair", "fail-stop", "repair"]


def test_network_degrade_applies_and_restores_only_link_component():
    """net-degrade scales the comm share of every resident device; clearing
    it must not resurrect a dead device or heal a compute straggler."""
    from repro.cluster.events import apply_event

    topo = ClusterTopology(2, 4)
    cluster = ClusterState(topo)
    cluster.fail_stop(1)
    cluster.fail_slow(2, 0.5)
    tr = NetworkDegrade(node=0, link_scale=0.5, at=10.0,
                        duration=20.0).compile(topo, 0)
    assert [ev.kind for ev in tr] == ["net-degrade", "net-restore"]
    apply_event(tr[0], cluster, 10.0)
    # comm_share=0.3 at half bandwidth: 1/((1-.3)+.3/.5) = 1/1.3
    assert cluster.devices[0].effective == pytest.approx(1 / 1.3)
    assert cluster.devices[2].effective == pytest.approx(0.5 / 1.3)
    assert cluster.devices[1].effective == 0.0  # dead stays dead
    assert cluster.devices[4].effective == 1.0  # other node untouched
    apply_event(tr[1], cluster, 30.0)
    assert cluster.devices[0].effective == 1.0
    assert not cluster.devices[1].alive  # restore is network-only
    assert cluster.devices[2].effective == pytest.approx(0.5)  # still slow


def test_slow_ramp_monotone_degradation():
    ramp = FailSlow(device=1, severity=0.4, at=10.0, ramp=8.0, ramp_steps=4)
    tr = ramp.compile(TOPO, 0)
    speeds = [ev.value for ev in tr if ev.kind == "fail-slow"]
    assert len(speeds) == 4
    assert speeds == sorted(speeds, reverse=True)  # monotone ramp down
    assert speeds[-1] == pytest.approx(0.4)


def test_poisson_storm_distinct_devices_with_repairs():
    storm = PoissonFailures(rate=0.5, t_end=100.0, mttr=10.0)
    tr = storm.compile(TOPO, 9)
    fails = [ev for ev in tr if ev.kind in ("fail-stop", "fail-slow")]
    assert len(fails) > 0
    targets = [ev.target for ev in fails]
    assert len(targets) == len(set(targets))  # no double-kill
    rejoins = {ev.target for ev in tr if ev.kind == "rejoin"}
    assert rejoins == set(targets)


def test_poisson_renewal_mode_refails_repaired_devices():
    """renewal=True returns repaired devices to the victim pool; the default
    distinct-device mode stops once every device has been hit once."""
    topo4 = ClusterTopology(1, 4)  # tiny fleet so the pool exhausts quickly
    kw = dict(rate=1.0, t_end=200.0, mttr=2.0, max_events=24)
    default = PoissonFailures(**kw).compile(topo4, 0)
    renewal = PoissonFailures(renewal=True, **kw).compile(topo4, 0)

    def fail_targets(tr):
        return [ev.target for ev in tr
                if ev.kind in ("fail-stop", "fail-slow")]

    d_hits, r_hits = fail_targets(default), fail_targets(renewal)
    assert len(d_hits) == len(set(d_hits)) <= 4  # distinct-device contract
    assert len(r_hits) > len(set(r_hits))  # some device failed again
    # a device is never re-failed before its repair completed
    down_until: dict = {}
    for ev in renewal:
        if ev.kind in ("fail-stop", "fail-slow"):
            assert ev.t >= down_until.get(ev.target, 0.0)
        elif ev.kind == "rejoin":
            down_until[ev.target] = ev.t
    # deterministic like every other scenario
    assert renewal.to_json() == \
        PoissonFailures(renewal=True, **kw).compile(topo4, 0).to_json()


# --------------------------------------------------------- simulator wiring
def test_apply_scenario_fires_events_in_sim():
    sim = TrainingSim("resihp", SMALL)
    tr = sim.apply_scenario(FailSlow(device=3, severity=0.5, at=0.1))
    assert len(tr) == 1 and len(sim.pending_events) == 1
    sim.run(12)
    assert not sim.pending_events
    assert [ev.kind for ev in sim.event_log] == ["fail-slow"]
    assert sim.cluster.devices[3].speed == pytest.approx(0.5)


def test_apply_scenario_by_name_and_seed_determinism():
    sims = [TrainingSim("resihp", SMALL) for _ in range(2)]
    traces = [s.apply_scenario("fig10_mixed", seed=5) for s in sims]
    assert traces[0].to_json() == traces[1].to_json()


def test_rejoin_event_updates_system_belief():
    sim = TrainingSim("resihp", SMALL)
    sim.apply_scenario(FailStop(at=0.1, device=3)
                       + scenarios.Rejoin(device=3, at=1.0))
    sim.run(80)
    assert sim.cluster.devices[3].alive
    assert sim.known_speeds[3] == 1.0  # belief restored, not just hardware
    kinds = [ev.kind for ev in sim.event_log]
    assert kinds == ["fail-stop", "rejoin"]


def test_inject_at_shim_still_works():
    sim = TrainingSim("resihp", SMALL)
    sim.inject_at(0.1, lambda c, now: c.fail_slow(1, 0.6, now))
    sim.run(12)
    assert sim.cluster.devices[1].speed == pytest.approx(0.6)
    assert [ev.kind for ev in sim.event_log] == ["callback"]


def test_callback_trace_not_serializable():
    tr = EventTrace([Event(1.0, "callback", fn=lambda c, now: None)])
    with pytest.raises(ValueError):
        tr.to_json()


def test_event_trace_export_is_json():
    tr = scenarios.get("table6_failstop", n_failures=4).compile(TOPO, 0)
    rows = json.loads(tr.to_json())
    assert len(rows) == 4
    for t, kind, target, value, scen in rows:
        assert kind == "fail-stop" and 0 <= target < TOPO.n_devices
